"""Solver substrate tests: LU, triangular, GMRES, GMRES-IR."""
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.linalg as sla
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.precision import FORMAT_ID, FORMATS
from repro.solvers import (CONVERGED, FAILED, IRConfig, STAGNATED, gmres_ir,
                           gmres_ir_batch, gmres_precond, lu_factor,
                           lu_factor_blocked, lu_solve, solve_unit_lower,
                           solve_upper)

RNG = np.random.default_rng(42)
FP64 = FORMAT_ID["fp64"]
FP32 = FORMAT_ID["fp32"]
BF16 = FORMAT_ID["bf16"]
TF32 = FORMAT_ID["tf32"]


def rand_system(n, kappa=None, rng=RNG):
    if kappa is None:
        A = rng.standard_normal((n, n))
    else:
        q1, _ = np.linalg.qr(rng.standard_normal((n, n)))
        q2, _ = np.linalg.qr(rng.standard_normal((n, n)))
        s = np.ones(n)
        s[-1] = 1.0 / kappa
        A = (q1 * s) @ q2.T
    x = rng.standard_normal(n)
    return A, A @ x, x


# ---------------------------------------------------------------------------
# LU
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [4, 17, 64])
def test_lu_fp64_matches_numpy(n):
    A, b, x = rand_system(n)
    lu = lu_factor(jnp.asarray(A), FP64)
    assert not bool(lu.fail)
    # P A = L U
    L = np.tril(np.asarray(lu.lu), -1) + np.eye(n)
    U = np.triu(np.asarray(lu.lu))
    PA = A[np.asarray(lu.perm)]
    np.testing.assert_allclose(L @ U, PA, atol=1e-10 * np.abs(A).max() * n)
    got = np.asarray(lu_solve(lu.lu, lu.perm, jnp.asarray(b), FP64))
    np.testing.assert_allclose(got, np.linalg.solve(A, b), rtol=0, atol=1e-9)


def test_lu_partial_pivoting_stability():
    """Matrix requiring pivoting (tiny leading pivot)."""
    A = np.array([[1e-20, 1.0], [1.0, 1.0]])
    lu = lu_factor(jnp.asarray(A), FP64)
    b = np.array([1.0, 2.0])
    got = np.asarray(lu_solve(lu.lu, lu.perm, jnp.asarray(b), FP64))
    np.testing.assert_allclose(got, np.linalg.solve(A, b), rtol=1e-12)


def test_lu_low_precision_error_scales_with_u():
    A, b, x = rand_system(48, kappa=10)
    errs = {}
    for name in ["bf16", "fp32", "fp64"]:
        lu = lu_factor(jnp.asarray(A), FORMAT_ID[name])
        got = np.asarray(lu_solve(lu.lu, lu.perm, jnp.asarray(b),
                                  FORMAT_ID[name]))
        errs[name] = np.max(np.abs(got - x)) / np.max(np.abs(x))
    assert errs["bf16"] > errs["fp32"] > errs["fp64"]
    assert errs["bf16"] < 48 * 10 * FORMATS["bf16"].unit_roundoff * 10


def test_lu_overflow_sets_fail():
    """fp16 overflows on entries beyond 65504."""
    A = np.diag(np.full(8, 1e6))
    lu = lu_factor(jnp.asarray(A), FORMAT_ID["fp16"])
    assert bool(lu.fail)


def test_lu_blocked_matches_strict_fp64():
    A, _, _ = rand_system(64)
    s = lu_factor(jnp.asarray(A), FP64)
    blk = lu_factor_blocked(jnp.asarray(A), FP64, block=16)
    xs = np.asarray(lu_solve(s.lu, s.perm, jnp.ones(64), FP64))
    xb = np.asarray(lu_solve(blk.lu, blk.perm, jnp.ones(64), FP64))
    np.testing.assert_allclose(xs, xb, rtol=1e-8, atol=1e-10)


# ---------------------------------------------------------------------------
# Triangular solves
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [3, 32])
def test_triangular_fp64_exactish(n):
    Lfull = np.tril(RNG.standard_normal((n, n)), -1)
    U = np.triu(RNG.standard_normal((n, n))) + np.eye(n) * n
    b = RNG.standard_normal(n)
    comb = Lfull + U
    y = np.asarray(solve_unit_lower(jnp.asarray(comb), jnp.asarray(b), FP64))
    np.testing.assert_allclose(y, sla.solve_triangular(Lfull + np.eye(n), b,
                                                       lower=True), rtol=1e-10)
    x = np.asarray(solve_upper(jnp.asarray(comb), jnp.asarray(b), FP64))
    np.testing.assert_allclose(x, sla.solve_triangular(U, b), rtol=1e-8)


# ---------------------------------------------------------------------------
# GMRES
# ---------------------------------------------------------------------------

def test_gmres_solves_preconditioned_system():
    n = 48
    A, b, x = rand_system(n, kappa=100)
    lu = lu_factor(jnp.asarray(A), FP64)
    res = gmres_precond(jnp.asarray(A), lu.lu, lu.perm, jnp.asarray(b),
                        FP64, m_max=30, tol=1e-12)
    assert not bool(res.fail)
    np.testing.assert_allclose(np.asarray(res.z), x, rtol=0, atol=1e-8)
    assert int(res.iters) <= 3  # exact preconditioner => ~1 iteration


def test_gmres_low_precision_needs_more_iterations():
    n = 48
    A, b, x = rand_system(n, kappa=1000)
    lo = lu_factor(jnp.asarray(A), BF16)
    hi = lu_factor(jnp.asarray(A), FP64)
    r_lo = gmres_precond(jnp.asarray(A), lo.lu, lo.perm, jnp.asarray(b),
                         FP64, m_max=40, tol=1e-10)
    r_hi = gmres_precond(jnp.asarray(A), hi.lu, hi.perm, jnp.asarray(b),
                         FP64, m_max=40, tol=1e-10)
    assert int(r_lo.iters) > int(r_hi.iters)


# ---------------------------------------------------------------------------
# GMRES-IR end to end
# ---------------------------------------------------------------------------

def test_ir_fp64_baseline_two_iterations():
    """The paper's FP64 baseline accounting: exactly 2 outer iterations."""
    for kappa in [10, 1e5, 1e8]:
        A, b, x = rand_system(96, kappa=kappa)
        st_ = gmres_ir(jnp.asarray(A), jnp.asarray(b), jnp.asarray(x),
                       jnp.asarray([FP64] * 4, jnp.int32), IRConfig(tau=1e-6))
        assert int(st_.status) == CONVERGED
        assert int(st_.n_outer) == 2
        assert float(st_.nbe) < 1e-15


def test_ir_low_precision_factorization_converges_wellconditioned():
    A, b, x = rand_system(96, kappa=50)
    act = jnp.asarray([BF16, FP64, FP64, FP64], jnp.int32)
    st_ = gmres_ir(jnp.asarray(A), jnp.asarray(b), jnp.asarray(x), act,
                   IRConfig(tau=1e-6))
    assert int(st_.status) == CONVERGED
    assert float(st_.ferr) < 1e-10
    assert int(st_.n_gmres) > 2  # pays extra inner iterations


def test_ir_all_low_precision_degrades():
    A, b, x = rand_system(96, kappa=1e4)
    act = jnp.asarray([BF16, BF16, BF16, BF16], jnp.int32)
    st_ = gmres_ir(jnp.asarray(A), jnp.asarray(b), jnp.asarray(x), act,
                   IRConfig(tau=1e-6))
    assert float(st_.ferr) > 1e-6  # cannot reach fp64-level accuracy


def test_ir_singular_matrix_fails():
    A = np.zeros((16, 16))
    st_ = gmres_ir(jnp.asarray(A), jnp.ones(16), jnp.ones(16),
                   jnp.asarray([FP64] * 4, jnp.int32), IRConfig())
    assert int(st_.status) == FAILED


def test_ir_batch_matches_single():
    systems = [rand_system(48, kappa=k) for k in [10, 1e4, 1e7]]
    A = jnp.asarray(np.stack([s[0] for s in systems]))
    b = jnp.asarray(np.stack([s[1] for s in systems]))
    x = jnp.asarray(np.stack([s[2] for s in systems]))
    acts = jnp.asarray(np.stack([[FP64] * 4, [FP32, FP64, FP64, FP64],
                                 [BF16, FP32, FP64, FP64]]), jnp.int32)
    cfg = IRConfig(tau=1e-6)
    batch = gmres_ir_batch(A, b, x, acts, cfg)
    for i in range(3):
        single = gmres_ir(A[i], b[i], x[i], acts[i], cfg)
        assert int(batch.status[i]) == int(single.status)
        assert int(batch.n_outer[i]) == int(single.n_outer)
        np.testing.assert_allclose(float(batch.ferr[i]), float(single.ferr),
                                   rtol=1e-12)


def test_ir_padded_system_equivalent():
    """Identity-padding must not change the solution quality (DESIGN §3)."""
    A, b, x = rand_system(48, kappa=1e3)
    n_pad = 64
    Ap = np.eye(n_pad)
    Ap[:48, :48] = A
    bp = np.zeros(n_pad)
    bp[:48] = b
    xp = np.zeros(n_pad)
    xp[:48] = x
    act = jnp.asarray([FP32, FP64, FP64, FP64], jnp.int32)
    st0 = gmres_ir(jnp.asarray(A), jnp.asarray(b), jnp.asarray(x), act,
                   IRConfig(tau=1e-6))
    st1 = gmres_ir(jnp.asarray(Ap), jnp.asarray(bp), jnp.asarray(xp), act,
                   IRConfig(tau=1e-6))
    assert int(st1.status) == CONVERGED
    assert abs(np.log10(float(st0.ferr)) - np.log10(float(st1.ferr))) < 2.0


# ---------------------------------------------------------------------------
# Property tests
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=8, max_value=40),
       st.sampled_from([1e1, 1e3, 1e6]))
def test_prop_monotone_precision_error(n, kappa):
    """Error is (weakly) monotone in factorization precision."""
    rng = np.random.default_rng(n * 1000 + int(np.log10(kappa)))
    A, b, x = rand_system(n, kappa=kappa, rng=rng)
    cfg = IRConfig(tau=1e-8, i_max=6)
    errs = []
    for fid in [BF16, FP32, FP64]:
        act = jnp.asarray([fid, FP64, FP64, FP64], jnp.int32)
        st_ = gmres_ir(jnp.asarray(A), jnp.asarray(b), jnp.asarray(x), act,
                       cfg)
        errs.append(float(st_.ferr))
    # Converged IR reaches the same error floor regardless of u_f, but
    # non-converged low-precision runs must not be better than fp64.
    assert errs[0] >= errs[2] * 0.01
    assert errs[1] >= errs[2] * 0.01
