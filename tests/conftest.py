"""Test configuration.

x64 is enabled because the solver substrate reproduces the paper's FP64
experiments on the CPU host. Model/LM code pins explicit dtypes everywhere,
so it is insensitive to this flag. The 512-device dry-run flag is
deliberately NOT set here — smoke tests must see the real (1-device) host;
dry-run tests spawn a subprocess instead.
"""
import importlib.util
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

# Property tests use `hypothesis` (declared in requirements.txt). When the
# execution environment lacks it, fall back to the deterministic stub so the
# four property-test modules still collect and run.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_stub",
        os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py"))
    _stub = importlib.util.module_from_spec(_spec)
    sys.modules["_hypothesis_stub"] = _stub
    _spec.loader.exec_module(_stub)
    _stub.install()
