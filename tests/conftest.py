"""Test configuration.

x64 is enabled because the solver substrate reproduces the paper's FP64
experiments on the CPU host. Model/LM code pins explicit dtypes everywhere,
so it is insensitive to this flag. The 512-device dry-run flag is
deliberately NOT set here — smoke tests must see the real (1-device) host;
dry-run tests spawn a subprocess instead.
"""
import jax

jax.config.update("jax_enable_x64", True)
