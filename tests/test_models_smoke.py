"""Per-architecture smoke tests: reduced configs, one forward + one train
step + one decode step on CPU; asserts shapes and finiteness (assignment
requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, all_archs, get_smoke, supports_long_context
from repro.models import decode_step, forward, init_caches, init_params, \
    loss_fn

ARCH_NAMES = sorted(all_archs().keys())
KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=64):
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.frontend == "vision_stub":
        batch["prefix_embeds"] = jax.random.normal(
            KEY, (b, cfg.n_prefix_embeds, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_shapes_no_nan(name):
    cfg = get_smoke(name)
    params = init_params(cfg, KEY, jnp.float32)
    batch = _batch(cfg)
    logits = forward(params, batch["tokens"], cfg, jnp.float32,
                     prefix_embeds=batch.get("prefix_embeds"))
    assert logits.shape == (2, 64, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))
    # loss near log(vocab) at init
    loss, aux = loss_fn(params, batch, cfg, jnp.float32)
    assert bool(jnp.isfinite(loss))
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.5


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_train_step_grads_finite(name):
    cfg = get_smoke(name)
    params = init_params(cfg, KEY, jnp.float32)
    batch = _batch(cfg, b=2, s=32)

    def loss_of(p):
        return loss_fn(p, batch, cfg, jnp.float32)[0]

    loss, grads = jax.value_and_grad(loss_of)(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    # gradient signal actually reaches the embedding
    gnorm = sum(float(jnp.sum(g * g)) for g in leaves)
    assert gnorm > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_decode_matches_forward(name):
    """Greedy decode logits == full-forward logits at each position."""
    cfg = get_smoke(name)
    params = init_params(cfg, KEY, jnp.float32)
    b, s = 2, 8
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    full = forward(params, tokens, cfg, jnp.float32)
    caches = init_caches(cfg, b, s, jnp.float32)
    outs = []
    for t in range(s):
        lg, caches = decode_step(params, tokens[:, t:t + 1], caches, cfg,
                                 jnp.float32)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-2, atol=2e-2)


def test_param_counts_match_published():
    expect = {
        "llama4-scout-17b-16e": (107.8, 17.2),
        "deepseek-v2-236b": (235.7, 21.4),
        "falcon-mamba-7b": (7.3, 7.3),
        "gemma2-9b": (9.2, 9.2),
        "phi4-mini-3.8b": (3.8, 3.8),
        "granite-3-2b": (2.5, 2.5),
        "gemma-2b": (2.5, 2.5),
        "jamba-v0.1-52b": (51.6, 12.1),
        "musicgen-large": (3.2, 3.2),
        "phi-3-vision-4.2b": (3.8, 3.8),
    }
    for name, (tot, act) in expect.items():
        cfg = ARCHS[name]
        assert abs(cfg.params_total() / 1e9 - tot) < 0.15, name
        assert abs(cfg.params_active() / 1e9 - act) < 0.15, name


def test_long_context_applicability():
    """DESIGN.md §4 skip list."""
    runs = {n for n in ARCH_NAMES if supports_long_context(ARCHS[n])}
    assert runs == {"llama4-scout-17b-16e", "falcon-mamba-7b", "gemma2-9b",
                    "jamba-v0.1-52b"}


def test_smoke_params_match_analytic_count():
    """init_params leaf count == ArchConfig analytic count (smoke scale)."""
    for name in ["gemma2-9b", "jamba-v0.1-52b", "deepseek-v2-236b",
                 "falcon-mamba-7b"]:
        cfg = get_smoke(name)
        params = init_params(cfg, KEY, jnp.float32)
        got = sum(x.size for x in jax.tree_util.tree_leaves(params))
        want = cfg.params_total()
        assert abs(got - want) / want < 0.02, (name, got, want)
