"""Observability layer: fail-open metrics, Prometheus exposition, the
HTTP front door, request tracing, and the trajectory log.

The load-bearing test here is the fault-injection one: a server whose
sinks / tracer / trajectory log all raise must produce bit-identical
responses to a server with observability disabled — instrumentation can
never change a solve result or drop a response (DESIGN.md §8.1)."""
import json
import os
import shutil
import sys
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import GMRESIREnv, TrainConfig, W1, reduced_action_space
from repro.obs import (MetricsRegistry, Observability, Tracer,
                       TrajectoryLog, default_registry, fail_open,
                       lint_exposition, render_json, render_prometheus)
from repro.service import (AutotuneServer, BatcherConfig, OnlineConfig,
                           PolicyRegistry, Telemetry)
from repro.data import generate_dense_set
from repro.solvers import IRConfig

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)

SPACE = reduced_action_space()
IR = IRConfig(tau=1e-6)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# Metrics registry: fail-open mutators, sinks, exposition
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("repro_t_requests_total", "Requests.", ("task",))
    c.labels(task="a").inc()
    c.labels(task="a").inc(2)
    c.labels(task="b").inc(0.5)
    assert c.labels(task="a").value == pytest.approx(3.0)
    assert c.labels(task="b").value == pytest.approx(0.5)

    g = reg.gauge("repro_t_pending", "Pending.")
    g.set(4)
    g.inc()
    g.dec(2)
    assert g.labels().value == pytest.approx(3.0)

    h = reg.histogram("repro_t_wait_seconds", "Wait.", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    child = h.labels()
    assert child.count == 3
    assert child.sum == pytest.approx(5.55)
    assert child.cumulative() == [1, 2, 3]     # le=0.1, le=1, +Inf
    assert reg.errors == 0

    # Families are get-or-create: same name returns the same object...
    assert reg.counter("repro_t_requests_total", "", ("task",)) is c
    # ...but re-registering with different labels is a hard error (a
    # programming bug, caught at construction, not on the hot path).
    with pytest.raises(ValueError):
        reg.counter("repro_t_requests_total", "", ("other",))


def test_metric_mutators_are_fail_open():
    reg = MetricsRegistry()
    c = reg.counter("repro_t_x_total", "X.")
    c.inc(5)
    c.inc(-1)                      # negative increment: rejected, counted
    c.inc(float("nan"))            # non-finite: rejected, counted
    assert c.labels().value == pytest.approx(5.0)
    assert reg.errors == 2

    g = reg.gauge("repro_t_g", "G.")
    g.set("not-a-number")          # ValueError swallowed
    assert g.labels().value == 0.0
    assert reg.errors == 3

    # Wrong label names raise *outside* the guard (facades reach labels()
    # only through fail_open-wrapped methods).
    with pytest.raises(ValueError):
        reg.counter("repro_t_lab_total", "", ("task",)).labels(wrong="x")


def test_raising_sink_is_counted_not_propagated():
    reg = MetricsRegistry()
    seen = []

    def bad_sink(name, labels, value):
        raise RuntimeError("exporter down")

    reg.add_sink(bad_sink)
    reg.add_sink(lambda name, labels, value: seen.append((name, value)))
    c = reg.counter("repro_t_sink_total", "S.")
    c.inc()
    c.inc()
    # The raising sink never reaches the caller, is counted per sample,
    # and does not starve the healthy sink registered after it.
    assert c.labels().value == 2.0
    assert reg.errors == 2
    assert seen == [("repro_t_sink_total", 1.0), ("repro_t_sink_total", 2.0)]


def test_fail_open_decorator_counts_and_returns_none():
    reg = MetricsRegistry()

    class Facade:
        def __init__(self):
            self.registry = reg

        @fail_open
        def boom(self):
            raise RuntimeError("instrumentation bug")

        @fail_open
        def ok(self):
            return 42

    f = Facade()
    assert f.boom() is None
    assert f.ok() == 42
    assert reg.errors == 1


def test_default_registry_is_a_process_singleton():
    assert default_registry() is default_registry()
    assert Observability().registry is default_registry()
    assert Observability(registry=MetricsRegistry()).registry \
        is not default_registry()


def test_prometheus_exposition_golden_format():
    reg = MetricsRegistry()
    reg.gauge("repro_demo_pending", "Pending.").set(2)
    reg.counter("repro_demo_requests_total", "Demo requests.",
                ("task",)).labels(task="gmres").inc(3)
    h = reg.histogram("repro_demo_wait_seconds", "Wait.", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(5.0)
    assert render_prometheus(reg) == (
        "# HELP repro_demo_pending Pending.\n"
        "# TYPE repro_demo_pending gauge\n"
        "repro_demo_pending 2\n"
        "# HELP repro_demo_requests_total Demo requests.\n"
        "# TYPE repro_demo_requests_total counter\n"
        'repro_demo_requests_total{task="gmres"} 3\n'
        "# HELP repro_demo_wait_seconds Wait.\n"
        "# TYPE repro_demo_wait_seconds histogram\n"
        'repro_demo_wait_seconds_bucket{le="0.1"} 1\n'
        'repro_demo_wait_seconds_bucket{le="1"} 1\n'
        'repro_demo_wait_seconds_bucket{le="+Inf"} 2\n'
        "repro_demo_wait_seconds_sum 5.05\n"
        "repro_demo_wait_seconds_count 2\n"
        "# HELP repro_obs_errors_total Instrumentation exceptions "
        "swallowed by the fail-open guard.\n"
        "# TYPE repro_obs_errors_total counter\n"
        "repro_obs_errors_total 0\n")
    assert lint_exposition(render_prometheus(reg)) == []
    js = render_json(reg)
    assert js["repro_demo_requests_total"]["samples"][0] == {
        "labels": {"task": "gmres"}, "value": 3.0}
    assert js["repro_demo_wait_seconds"]["samples"][0]["count"] == 2


def test_exposition_lint_catches_violations():
    bad = (
        "# TYPE bad_metric counter\n"
        "bad_metric 1\n"
        "# TYPE repro_foo counter\n"
        "repro_foo 2\n"
        "# TYPE repro_request_latency histogram\n"
        'repro_request_latency_bucket{le="+Inf"} 1\n'
        "repro_request_latency_sum 1\n"
        "repro_request_latency_count 1\n"
        'repro_thing{BadLabel="x"} 1\n')
    problems = "\n".join(lint_exposition(bad))
    assert "bad_metric" in problems and "repro_" in problems
    assert "repro_foo" in problems and "_total" in problems
    assert "repro_request_latency" in problems and "_seconds" in problems
    assert "BadLabel" in problems


# ---------------------------------------------------------------------------
# Tracer + trajectory log (unit)
# ---------------------------------------------------------------------------

def test_tracer_ring_is_bounded_and_filterable():
    tr = Tracer(capacity=4)
    for i in range(6):
        tr.add_span("s", t0=float(i), t1=float(i) + 0.5, tid=i % 2)
    assert len(tr) == 4                       # ring kept the most recent
    assert [s.t0 for s in tr.spans()] == [2.0, 3.0, 4.0, 5.0]
    assert all(s.tid == 1 for s in tr.spans(tid=1))
    ev = tr.chrome_trace()["traceEvents"]
    assert len(ev) == 4
    assert ev[0] == {"name": "s", "cat": "request", "ph": "X",
                     "ts": 2e6, "dur": 0.5e6, "pid": 0, "tid": 0}


def test_tracer_span_contextmanager_nests(tmp_path):
    clock = FakeClock()
    tr = Tracer(clock=clock)
    with tr.span("outer", tid=7):
        clock.advance(1.0)
        with tr.span("inner", tid=7, detail="x"):
            clock.advance(2.0)
        clock.advance(1.0)
    inner, outer = tr.spans()                 # inner closes first
    assert (inner.name, outer.name) == ("inner", "outer")
    assert outer.t0 <= inner.t0 <= inner.t1 <= outer.t1
    assert inner.duration == pytest.approx(2.0)
    assert outer.duration == pytest.approx(4.0)
    assert inner.args == {"detail": "x"}
    path = tmp_path / "trace.json"
    tr.dump(str(path))
    with open(path) as f:
        assert len(json.load(f)["traceEvents"]) == 2


def test_trajectory_log_roundtrip_and_corruption_tolerance(tmp_path):
    path = str(tmp_path / "traj.jsonl")
    with TrajectoryLog(path) as log:
        log.append({"task": "a", "reward": np.float64(1.5),
                    "features": [np.float32(2.0)], "request_id": 0})
        log.append({"task": "b", "reward": 2.0, "request_id": 1})
        assert log.written == 2
    # Simulate a torn tail write of a crashed server.
    with open(path, "a") as f:
        f.write('{"task": "c", "rew')
    recs = TrajectoryLog.read(path)
    assert len(recs) == 2                     # corrupt tail skipped
    assert recs[0]["reward"] == 1.5           # numpy scalars -> floats
    assert recs[0]["features"] == [2.0]
    assert TrajectoryLog.read(path, task="b") == [
        {"task": "b", "reward": 2.0, "request_id": 1}]


def test_trajectory_log_rotates_on_size(tmp_path):
    path = str(tmp_path / "traj.jsonl")
    with TrajectoryLog(path, max_bytes=200, max_segments=2) as log:
        for i in range(40):
            log.append({"request_id": i, "task": "t"})
        assert log.rotations >= 2
    segs = TrajectoryLog.segments(path)
    assert segs[-1] == path                   # active file is newest
    assert len(segs) <= 3                     # .2, .1 + active
    for seg in segs:                          # bounded: limit + 1 record
        assert os.path.getsize(seg) <= 200 + 64
    # Readers span the live segments oldest-first: ids stay ordered, the
    # newest record survives, the oldest were rotated out and deleted.
    ids = [r["request_id"] for r in TrajectoryLog.read(path)]
    assert ids == sorted(ids)
    assert ids[-1] == 39
    assert 0 < len(ids) < 40


def test_trajectory_log_without_limit_never_rotates(tmp_path):
    path = str(tmp_path / "traj.jsonl")
    with TrajectoryLog(path) as log:
        for i in range(200):
            log.append({"request_id": i})
        assert log.rotations == 0
    assert TrajectoryLog.segments(path) == [path]
    assert len(TrajectoryLog.read(path)) == 200


def test_trajectory_log_truncation_at_segment_boundary(tmp_path):
    """A rotated segment whose tail was torn mid-record (crash during
    rotation, disk-full) loses exactly that record: the reader keeps
    every complete line in that segment and everything in the segments
    around it."""
    path = str(tmp_path / "traj.jsonl")
    with TrajectoryLog(path, max_bytes=120, max_segments=4) as log:
        for i in range(20):
            log.append({"request_id": i, "task": "t"})
        assert log.rotations >= 2
    segs = TrajectoryLog.segments(path)
    assert len(segs) >= 3
    victim = segs[1]                           # a middle rotated segment
    before = [json.loads(ln) for ln in open(victim) if ln.strip()]
    with open(victim, "rb+") as f:
        f.truncate(os.path.getsize(victim) - 7)   # tear the last record
    recs = list(TrajectoryLog.iter_records(path))
    ids = [r["request_id"] for r in recs]
    assert before[-1]["request_id"] not in ids    # torn record dropped
    for r in before[:-1]:                         # rest of segment kept
        assert r["request_id"] in ids
    assert ids == sorted(ids)                     # ordering undisturbed


def test_trajectory_log_iter_records_ordering_across_segments(tmp_path):
    """iter_records yields exactly the surviving append order — oldest
    rotated segment first, active file last, no interleaving."""
    path = str(tmp_path / "traj.jsonl")
    with TrajectoryLog(path, max_bytes=150, max_segments=3) as log:
        for i in range(30):
            log.append({"request_id": i})
    per_seg = [[json.loads(ln)["request_id"] for ln in open(seg)
                if ln.strip()]
               for seg in TrajectoryLog.segments(path)]
    flat = [i for seg in per_seg for i in seg]
    assert [r["request_id"]
            for r in TrajectoryLog.iter_records(path)] == flat
    assert flat == sorted(flat)                # oldest-first, contiguous
    assert flat[-1] == 29


def test_trajectory_log_append_after_rotation_keeps_ordering(tmp_path):
    """Appends after a rotation land in the fresh active file and read
    back *after* everything in the rotated segments, even across a
    writer reopen."""
    path = str(tmp_path / "traj.jsonl")
    log = TrajectoryLog(path, max_bytes=120, max_segments=3)
    for i in range(12):
        log.append({"request_id": i})
    assert log.rotations >= 1
    rotated_at = log.rotations
    log.append({"request_id": 100})            # post-rotation append
    log.close()
    # A new writer on the same path appends to the active file, not a
    # fresh segment.
    with TrajectoryLog(path, max_bytes=10**6, max_segments=3) as log2:
        log2.append({"request_id": 101})
        assert log2.rotations == 0
    ids = [r["request_id"] for r in TrajectoryLog.iter_records(path)]
    assert ids[-2:] == [100, 101]
    assert ids == sorted(ids)
    assert rotated_at >= 1


def test_trajectory_log_read_complete_filters_foreign_rows(tmp_path):
    """`read_complete` keeps only rows carrying the full OPE schema, so
    decision-trail events sharing a log file never reach the
    estimators."""
    path = str(tmp_path / "traj.jsonl")
    full = {f: 0 for f in TrajectoryLog.FIELDS}
    full.update(task="t", request_id=1)
    with TrajectoryLog(path) as log:
        log.append(full)
        log.append({"event": "ope_gate", "outcome": "ope_reject",
                    "task": "t"})              # trail event, same task
        log.append(dict(full, request_id=2))
    recs = TrajectoryLog.read_complete(path, task="t")
    assert [r["request_id"] for r in recs] == [1, 2]
    # Narrower field sets widen the net.
    assert len(TrajectoryLog.read_complete(
        path, task="t", fields=("task",))) == 3


# ---------------------------------------------------------------------------
# Telemetry satellites: throughput anchor, per-bucket reservoirs
# ---------------------------------------------------------------------------

def test_throughput_window_is_anchored_at_first_submit():
    tel = Telemetry()
    tel.on_submit(16, now=10.0)
    tel.on_response(2.0, ("fp32",), 0, 1.0, now=12.0, bucket=16)
    # One response over the [first submit, last response] window: 1/2 s.
    # The old first-response anchor reported 0 for exactly this case.
    assert tel.throughput_rps == pytest.approx(0.5)
    tel.on_response(1.0, ("fp32",), 0, 1.0, now=14.0, bucket=16)
    assert tel.throughput_rps == pytest.approx(2 / 4.0)
    assert tel.snapshot()["throughput_rps"] == pytest.approx(0.5)


def test_per_bucket_latency_reservoirs():
    tel = Telemetry(max_bucket_latency_samples=4)
    for v in (1.0, 2.0, 3.0, 4.0, 5.0):
        tel.on_response(v, (), 0, 0.0, now=v, bucket=16)
    tel.on_response(10.0, (), 0, 0.0, now=6.0, bucket=32)
    pb = tel.latency_percentiles_per_bucket()
    assert set(pb) == {16, 32}
    # Bounded reservoir: bucket 16 kept the most recent 4 samples.
    assert pb[16]["p50"] == pytest.approx(3.5)
    assert pb[32]["p99"] == pytest.approx(10.0)
    snap = tel.snapshot()
    assert snap["latency_s_per_bucket"][16]["p99"] == pytest.approx(
        np.percentile([2.0, 3.0, 4.0, 5.0], 99))


def test_backend_fallback_is_counted():
    import jax
    if jax.default_backend() == "tpu":
        pytest.skip("pallas is the real fast path on TPU; no fallback")
    import warnings

    from repro.precision.backend import resolve_backend
    fam = default_registry().counter(
        "repro_backend_fallbacks_total", "", ("requested", "served"))
    child = fam.labels(requested="pallas", served="jnp")
    before = child.value
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert resolve_backend("pallas").name == "jnp"
    assert child.value == before + 1


# ---------------------------------------------------------------------------
# Service integration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def warm_root(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("obsreg") / "reg")
    rng = np.random.default_rng(7)
    train = generate_dense_set(6, rng, n_range=(12, 28),
                               log10_kappa_range=(1, 6))
    env = GMRESIREnv(train, SPACE, IR, chunk=4, bucket_step=16)
    PolicyRegistry.warm_start(root, env, W1, TrainConfig(episodes=2))
    return root


def _server(root, obs, clock=None, seed=0):
    return AutotuneServer(
        PolicyRegistry(root), IR, W1,
        BatcherConfig(max_batch=4, max_wait_s=0.005,
                      bucket_step=16, min_bucket=16),
        OnlineConfig(), clock=clock or time.monotonic, seed=seed, obs=obs)


def _requests(n, seed, n_range=(12, 28)):
    rng = np.random.default_rng(seed)
    return generate_dense_set(n, rng, n_range, log10_kappa_range=(1, 6))


class _BrokenTracer(Tracer):
    def add_span(self, *a, **k):
        raise RuntimeError("tracer down")


class _BrokenLog:
    def append(self, record):
        raise OSError("disk full")

    def close(self):
        pass


def test_injected_obs_faults_never_change_solve_results(warm_root):
    """The acceptance property of the whole layer (DESIGN.md §8.1): a
    server whose exporter sink, tracer, or trajectory log raises on
    every call returns byte-for-byte the same responses as one with
    observability disabled — and reports the faults it swallowed."""
    reqs = _requests(8, seed=3)

    def run(obs):
        srv = _server(warm_root, obs, clock=FakeClock(), seed=0)
        ids = [srv.submit(s) for s in reqs]
        srv.drain()
        out = [srv.poll(i) for i in ids]
        assert srv.pending == 0 and all(r is not None for r in out)
        return out

    base = run(False)                          # observability disabled

    reg_a = MetricsRegistry()
    reg_a.add_sink(lambda *a: (_ for _ in ()).throw(RuntimeError("sink")))
    broken_sink_and_tracer = Observability(registry=reg_a,
                                           tracer=_BrokenTracer())
    got_a = run(broken_sink_and_tracer)

    reg_b = MetricsRegistry()
    broken_trajlog = Observability(registry=reg_b)
    broken_trajlog.trajlog = _BrokenLog()
    got_b = run(broken_trajlog)

    for got, reg in ((got_a, reg_a), (got_b, reg_b)):
        for r, b in zip(got, base):
            assert r.request_id == b.request_id
            assert r.action == b.action and r.state == b.state
            assert r.bucket == b.bucket
            assert r.reward == b.reward        # exact, not approx
            assert r.eps == b.eps and r.drift == b.drift
            assert int(r.record.status) == int(b.record.status)
            assert float(r.record.cost) == float(b.record.cost)
        assert reg.errors > 0                  # faults were accounted


def _get(url):
    try:
        with urllib.request.urlopen(url, timeout=10) as resp:
            return resp.status, resp.read().decode(), \
                resp.headers.get("Content-Type", "")
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode(), e.headers.get("Content-Type", "")


def test_http_front_door_live_scrape(warm_root):
    srv = _server(warm_root, Observability(registry=MetricsRegistry()))
    http = srv.serve_obs()
    try:
        assert srv.serve_obs() is http         # idempotent

        code, body, _ = _get(http.url + "/healthz")
        health = json.loads(body)
        assert code == 200 and health["status"] == "ok"
        # Degradation surface (DESIGN.md §11.2) rides on /healthz: a
        # fresh server has no open breakers and nothing quarantined.
        assert health["open_buckets"] == [] and health["breakers"] == {}
        assert health["quarantined_updates"] == 0
        assert health["expired_requests"] == 0

        # Unready until the bucket grid is warm (nothing flushed yet).
        code, body, _ = _get(http.url + "/readyz")
        assert code == 503 and json.loads(body)["status"] == "unready"

        for s in _requests(4, seed=5, n_range=(12, 14)):   # one bucket
            srv.submit(s)
        srv.drain()
        code, body, _ = _get(http.url + "/readyz")
        assert code == 200 and json.loads(body)["status"] == "ready"

        # /metrics: Prometheus text format, convention-clean, and the
        # serving families are present with real samples.
        code, text, ctype = _get(http.url + "/metrics")
        assert code == 200 and ctype.startswith("text/plain")
        assert "version=0.0.4" in ctype
        assert lint_exposition(text) == []
        assert 'repro_service_requests_total{task="gmres_ir",bucket="16"} 4' \
            in text
        assert "repro_service_request_latency_seconds_bucket" in text
        assert "repro_obs_errors_total 0" in text
        assert 'repro_obs_scrapes_total{path="/readyz"} 2' in text

        code, body, ctype = _get(http.url + "/metrics.json")
        assert code == 200 and ctype.startswith("application/json")
        js = json.loads(body)
        assert js["repro_service_responses_total"]["type"] == "counter"

        code, body, _ = _get(http.url + "/telemetry")
        assert code == 200 and json.loads(body)["responses"] == 4

        code, body, _ = _get(http.url + "/trace")
        assert code == 200 and json.loads(body)["traceEvents"]

        code, body, _ = _get(http.url + "/nope")
        assert code == 404 and json.loads(body)["error"] == "not found"
    finally:
        srv.obs.close()


def test_request_spans_order_and_trajectory_log_roundtrip(warm_root,
                                                          tmp_path):
    path = str(tmp_path / "traj.jsonl")
    obs = Observability(registry=MetricsRegistry(), trajectory_path=path)
    srv = _server(warm_root, obs)
    reqs = _requests(8, seed=9)
    ids = [srv.submit(s) for s in reqs]
    srv.drain()
    resp = {i: srv.poll(i) for i in ids}

    # Six spans per request, chained contiguously inside the envelope:
    # submit -> queue_wait -> solve -> reward -> q_update.
    for rid in ids:
        spans = {s.name: s for s in obs.tracer.spans(tid=rid)}
        assert set(spans) == {"request", "submit", "queue_wait", "solve",
                              "reward", "q_update"}
        for s in spans.values():
            assert s.t1 >= s.t0
        assert spans["request"].t0 == spans["submit"].t0
        assert spans["submit"].t1 == spans["queue_wait"].t0
        assert spans["queue_wait"].t1 == spans["solve"].t0
        assert spans["solve"].t1 == spans["reward"].t0
        assert spans["reward"].t1 == spans["q_update"].t0
        assert spans["q_update"].t1 == pytest.approx(spans["request"].t1)
        assert spans["solve"].args["n_rows"] >= 1
        assert spans["request"].args["action"] == resp[rid].action

    # Trajectory log: one record per response, full schema, matching
    # the polled values.
    obs.close()
    recs = TrajectoryLog.read(path)
    assert len(recs) == len(ids)
    by_id = {r["request_id"]: r for r in recs}
    for i in ids:
        rec, r = by_id[i], resp[i]
        assert set(TrajectoryLog.FIELDS) <= set(rec)
        assert rec["action"] == r.action and rec["state"] == r.state
        assert rec["reward"] == pytest.approx(r.reward)
        assert rec["bucket"] == r.bucket
        assert isinstance(rec["explore"], bool)
        assert 0.0 <= rec["eps"] <= 1.0
        assert rec["policy_version"] == r.policy_version
        assert all(isinstance(x, float) for x in rec["features"])
        assert rec["outcome"]["status"] == int(r.record.status)


def test_snapshot_embeds_telemetry_evidence(warm_root, tmp_path):
    root = str(tmp_path / "reg")
    shutil.copytree(warm_root, root)           # keep the shared fixture
    srv = _server(root, Observability(registry=MetricsRegistry()))
    for s in _requests(4, seed=11, n_range=(12, 14)):
        srv.submit(s)
    srv.drain()
    version = srv.snapshot()
    tel = srv.registry.meta(version)["telemetry"]
    assert tel["responses"] == 4
    assert {"reward_ewma", "abs_rpe_ewma", "drift_events",
            "throughput_rps", "latency_s",
            "latency_s_per_bucket"} <= set(tel)
    assert tel["throughput_rps"] > 0
    assert {"p50", "p90", "p99"} <= set(tel["latency_s"])
    # JSON round-trip stringifies bucket keys; the one bucket is 16.
    (bucket,) = tel["latency_s_per_bucket"]
    assert int(bucket) == 16
    assert tel["latency_s_per_bucket"][bucket]["p99"] >= 0
    text = render_prometheus(srv.obs.registry)
    assert 'repro_service_snapshots_total{task="gmres_ir"} 1' in text
