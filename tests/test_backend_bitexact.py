"""Pallas precision backend vs the jnp oracle: bit-exactness + compile
accounting (DESIGN.md §6.2, §6.3).

Both backends run the *same* solver code; only the dispatched ops differ
(`chop` — identical integer RNE elementwise; `chop_mv` — shared
lane-padded row-sum reduction shape). So full GMRES-IR / CG-IR solver
outputs must be bit-identical on a shared f32 carrier, for every format
id, padded or not, single or batched, and end-to-end through the
`AutotuneEngine` and the serving stack. The pallas kernels run in
interpret mode so this suite is CPU-runnable (the CI docs job runs it).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import reduced_action_space
from repro.core.engine import AutotuneEngine
from repro.data.matrices import randsvd_dense, sparse_spd
from repro.precision import (FORMAT_ID, FORMAT_LIST, JnpBackend,
                             PallasBackend, resolve_backend)
from repro.service import AutotuneServer, BatcherConfig, OnlineConfig
from repro.solvers import BlockingPolicy, IRConfig, gmres_ir, gmres_ir_batch
from repro.solvers.cg import CGConfig, cg_ir, cg_ir_batch
from repro.tasks import CGIRTask, GMRESIRTask

RNG = np.random.default_rng(123)

# Shared f32 carrier on both sides; small chop_min_elems so the n^2
# roundings inside the solvers actually exercise the pallas chop kernel.
ORACLE = JnpBackend(carrier_dtype="float32")
PALLAS = PallasBackend(interpret=True, chop_min_elems=256)

IR = IRConfig(tau=1e-5, i_max=4, m_max=12)
CG = CGConfig(tau=1e-5, i_max=4, m_max=12)

# Threshold-lowered blocking so the small, cheap test systems exercise
# the blocked LU + blocked trisolve path end to end (DESIGN.md §6.4).
BLOCKED = BlockingPolicy(min_n=16, lu_block=16, trisolve_block=16)
IR_BLK = IRConfig(tau=1e-5, i_max=4, m_max=12, blocking=BLOCKED)
CG_BLK = CGConfig(tau=1e-5, i_max=4, m_max=12, blocking=BLOCKED)

ALL_FMT_IDS = list(range(len(FORMAT_LIST)))

# The `fast` marker names the subset the CI docs job runs (the full
# suite stays in the main tests job) — see [tool.pytest.ini_options].
FAST_FMT_IDS = (FORMAT_ID["fp32"], FORMAT_ID["bf16"])
FMT_PARAMS = [pytest.param(fid, marks=pytest.mark.fast)
              if fid in FAST_FMT_IDS else fid for fid in ALL_FMT_IDS]


def _dense(n, kappa=100.0, seed=0):
    s = randsvd_dense(n, kappa, np.random.default_rng(seed))
    return s.A, s.b, s.x_true


def _spd(n, seed=0):
    s = sparse_spd(n, 0.2, np.random.default_rng(seed), 1e4)
    return s.A, s.b, s.x_true


def _pad(A, b, x, n_pad):
    n = A.shape[0]
    Ap = np.eye(n_pad)
    Ap[:n, :n] = A
    bp = np.zeros(n_pad)
    bp[:n] = b
    xp = np.zeros(n_pad)
    xp[:n] = x
    return Ap, bp, xp


def _assert_stats_equal(got, want):
    for field, g, w in zip(got._fields, got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"field {field}")


# ---------------------------------------------------------------------------
# Solver outputs, all format ids, padded and unpadded
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("padded", [False, True])
@pytest.mark.parametrize("fid", FMT_PARAMS)
def test_gmres_ir_bitexact(fid, padded):
    A, b, x = _dense(20, kappa=50.0, seed=fid)
    if padded:
        A, b, x = _pad(A, b, x, 32)
    act = jnp.asarray([fid] * 4, jnp.int32)
    got = gmres_ir(A, b, x, act, IR, backend=PALLAS)
    want = gmres_ir(A, b, x, act, IR, backend=ORACLE)
    _assert_stats_equal(got, want)


@pytest.mark.parametrize("padded", [False, True])
@pytest.mark.parametrize("fid", FMT_PARAMS)
def test_cg_ir_bitexact(fid, padded):
    A, b, x = _spd(20, seed=fid)
    if padded:
        A, b, x = _pad(A, b, x, 32)
    act = jnp.asarray([fid] * 4, jnp.int32)
    got = cg_ir(A, b, x, act, CG, backend=PALLAS)
    want = cg_ir(A, b, x, act, CG, backend=ORACLE)
    _assert_stats_equal(got, want)


# ---------------------------------------------------------------------------
# Factorization path: blocked LU + blocked trisolve (DESIGN.md §6.4)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fid", FMT_PARAMS)
def test_gmres_ir_blocked_path_bitexact(fid):
    """Full GMRES-IR through blocked LU (chop_matmul trailing update)
    and blocked trisolves (chop_trisolve preconditioner applications):
    bit-identical across backends for every format id."""
    A, b, x = _dense(20, kappa=50.0, seed=40 + fid)
    act = jnp.asarray([fid] * 4, jnp.int32)
    got = gmres_ir(A, b, x, act, IR_BLK, backend=PALLAS)
    want = gmres_ir(A, b, x, act, IR_BLK, backend=ORACLE)
    _assert_stats_equal(got, want)


@pytest.mark.parametrize("fid", FMT_PARAMS)
def test_cg_ir_blocked_path_bitexact(fid):
    A, b, x = _spd(20, seed=40 + fid)
    act = jnp.asarray([fid] * 4, jnp.int32)
    got = cg_ir(A, b, x, act, CG_BLK, backend=PALLAS)
    want = cg_ir(A, b, x, act, CG_BLK, backend=ORACLE)
    _assert_stats_equal(got, want)


@pytest.mark.fast
def test_blocked_path_batched_bitexact():
    """vmapped blocked path: pallas kernels == oracle, and batched rows
    == single solves."""
    rows = [_dense(20, kappa=10.0 ** k, seed=50 + k) for k in range(1, 4)]
    A = np.stack([r[0] for r in rows])
    b = np.stack([r[1] for r in rows])
    x = np.stack([r[2] for r in rows])
    acts = jnp.asarray([[FORMAT_ID["fp32"]] * 4,
                        [FORMAT_ID["bf16"]] * 4,
                        [FORMAT_ID["fp16"], FORMAT_ID["fp32"],
                         FORMAT_ID["fp32"], FORMAT_ID["fp32"]]], jnp.int32)
    got = gmres_ir_batch(A, b, x, acts, IR_BLK, backend=PALLAS)
    want = gmres_ir_batch(A, b, x, acts, IR_BLK, backend=ORACLE)
    _assert_stats_equal(got, want)
    for i in range(3):
        single = gmres_ir(A[i], b[i], x[i], acts[i], IR_BLK, backend=PALLAS)
        for field, g, w in zip(single._fields, single, got):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w)[i],
                                          err_msg=f"row {i} field {field}")


def test_blocked_path_default_threshold_bitexact():
    """At n >= DEFAULT_BLOCKING.min_n the blocked path engages by
    default; the whole factorization + substitution pipeline must stay
    bit-identical across backends at that production size (the
    acceptance gate for making blocked the default).

    Scoped to the LU + trisolve pipeline rather than full GMRES-IR:
    whole-solver outputs at this size are limited by a pre-existing
    sensitivity of *unrounded* carrier reductions (GMRES norms) to each
    program's fusion context, which affects the strict path identically
    and is independent of the blocked subsystem (the small-n suites
    above cover full-solver bit-equality for both paths)."""
    from repro.solvers import DEFAULT_BLOCKING, lu_factor_auto, lu_solve
    n = DEFAULT_BLOCKING.min_n
    A, b, _ = _dense(n, kappa=100.0, seed=4)
    for fid in (FORMAT_ID["bf16"], FORMAT_ID["fp32"]):
        fj = lu_factor_auto(ORACLE.coerce(jnp.asarray(A)), fid,
                            backend=ORACLE, blocking=DEFAULT_BLOCKING)
        fp = lu_factor_auto(PALLAS.coerce(jnp.asarray(A)), fid,
                            backend=PALLAS, blocking=DEFAULT_BLOCKING)
        np.testing.assert_array_equal(np.asarray(fj.lu),
                                      np.asarray(fp.lu),
                                      err_msg=f"fmt {fid}")
        np.testing.assert_array_equal(np.asarray(fj.perm),
                                      np.asarray(fp.perm))
        xj = lu_solve(fj.lu, fj.perm, ORACLE.coerce(jnp.asarray(b)), fid,
                      backend=ORACLE, blocking=DEFAULT_BLOCKING)
        xp = lu_solve(fp.lu, fp.perm, PALLAS.coerce(jnp.asarray(b)), fid,
                      backend=PALLAS, blocking=DEFAULT_BLOCKING)
        np.testing.assert_array_equal(np.asarray(xj), np.asarray(xp),
                                      err_msg=f"fmt {fid}")


@pytest.mark.fast
def test_mixed_action_bitexact():
    """Per-step format ids differing across the four roles."""
    A, b, x = _dense(20, kappa=1e3, seed=99)
    act = jnp.asarray([FORMAT_ID["bf16"], FORMAT_ID["fp32"],
                       FORMAT_ID["fp16"], FORMAT_ID["fp32"]], jnp.int32)
    _assert_stats_equal(gmres_ir(A, b, x, act, IR, backend=PALLAS),
                        gmres_ir(A, b, x, act, IR, backend=ORACLE))


@pytest.mark.fast
def test_batched_bitexact_and_matches_single():
    """vmapped pallas kernels == vmapped oracle == per-row solves."""
    rows = [_dense(20, kappa=10.0 ** k, seed=k) for k in range(1, 4)]
    A = np.stack([r[0] for r in rows])
    b = np.stack([r[1] for r in rows])
    x = np.stack([r[2] for r in rows])
    acts = jnp.asarray([[FORMAT_ID["fp32"]] * 4,
                        [FORMAT_ID["bf16"]] * 4,
                        [FORMAT_ID["fp16"], FORMAT_ID["fp32"],
                         FORMAT_ID["fp32"], FORMAT_ID["fp32"]]], jnp.int32)
    got = gmres_ir_batch(A, b, x, acts, IR, backend=PALLAS)
    want = gmres_ir_batch(A, b, x, acts, IR, backend=ORACLE)
    _assert_stats_equal(got, want)
    for i in range(3):
        single = gmres_ir(A[i], b[i], x[i], acts[i], IR, backend=PALLAS)
        for field, g, w in zip(single._fields, single, got):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w)[i],
                                          err_msg=f"row {i} field {field}")


# ---------------------------------------------------------------------------
# Zero recompiles across precision actions (one executable per bucket)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", [ORACLE, PALLAS],
                         ids=["jnp", "pallas-interpret"])
def test_zero_recompiles_across_actions(backend):
    """Sweeping every action of the space through the engine must reuse
    ONE batched executable per size bucket (DESIGN.md §3.4, §6.3). The
    engine dispatches through the per-shape AOT executable cache
    (DESIGN.md §12), so the invariant is asserted there."""
    from repro.core.executor import batch_callable
    from repro.solvers import gmres_ir_batch_lowerable
    rng = np.random.default_rng(5)
    space = reduced_action_space()
    systems = [randsvd_dense(int(n), 100.0, rng) for n in (10, 12, 14)]
    task = GMRESIRTask(systems, space, IR, bucket_step=16, min_bucket=16,
                       backend=backend)
    engine = AutotuneEngine(task, chunk=4)
    wrapped = batch_callable(task.executor, None,
                             gmres_ir_batch_lowerable(IR, backend))
    before = len(wrapped.executables)
    engine.prefill_all()                     # every (instance, action) pair
    assert engine.n_solves == 3 * space.n_actions
    # One bucket (all n pad to 16) -> exactly one new executable.
    assert len(wrapped.executables) - before == 1


def test_zero_recompiles_cg_across_actions():
    from repro.core.executor import batch_callable
    from repro.solvers import cg_ir_batch_lowerable
    rng = np.random.default_rng(6)
    space = reduced_action_space()
    systems = [sparse_spd(int(n), 0.2, rng, 1e4) for n in (10, 12, 14)]
    task = CGIRTask(systems, space, CG, bucket_step=16, min_bucket=16,
                    backend=PALLAS)
    engine = AutotuneEngine(task, chunk=4)
    wrapped = batch_callable(task.executor, None,
                             cg_ir_batch_lowerable(CG, PALLAS))
    before = len(wrapped.executables)
    engine.prefill_all()
    assert len(wrapped.executables) - before == 1


# ---------------------------------------------------------------------------
# End to end: AutotuneEngine and the serving stack
# ---------------------------------------------------------------------------

def _engine_outcomes(task_cls, systems, cfg, backend):
    space = reduced_action_space()
    kw = ({"ir_cfg": cfg} if task_cls is GMRESIRTask else {"cg_cfg": cfg})
    task = task_cls(systems, space, bucket_step=16, min_bucket=16,
                    backend=backend, **kw)
    engine = AutotuneEngine(task, chunk=4)
    engine.prefill_all()
    return engine, space


@pytest.mark.parametrize("task_cls,gen,cfg", [
    (GMRESIRTask, _dense, IR), (CGIRTask, _spd, CG)],
    ids=["gmres_ir", "cg_ir"])
def test_engine_outcomes_bitexact(task_cls, gen, cfg):
    """The full engine path (bucketing, identity padding, fixed-chunk
    stacking, batched solve) produces bit-identical Outcomes on both
    backends for every (instance, action) pair."""
    rng = np.random.default_rng(7)
    if task_cls is GMRESIRTask:
        systems = [randsvd_dense(int(n), 100.0, rng) for n in (9, 11, 13)]
    else:
        systems = [sparse_spd(int(n), 0.2, rng, 1e4) for n in (9, 11, 13)]
    eng_p, space = _engine_outcomes(task_cls, systems, cfg, PALLAS)
    eng_j, _ = _engine_outcomes(task_cls, systems, cfg, ORACLE)
    for i in range(len(systems)):
        for a in range(space.n_actions):
            got = eng_p.outcome(i, a)
            want = eng_j.outcome(i, a)
            assert got.status == want.status, (i, a)
            assert got.metrics == want.metrics, (i, a)


def test_serving_stack_bitexact(tmp_path):
    """Same stream of requests through two AutotuneServers (pallas vs jnp
    oracle) with exploration off: identical actions, bit-identical
    Outcomes, identical rewards."""
    rng = np.random.default_rng(8)
    space = reduced_action_space()
    from repro.core import TrainConfig, W1
    from repro.service import PolicyRegistry

    train = [randsvd_dense(int(n), 50.0, rng) for n in (10, 12, 14, 11)]
    bcfg = BatcherConfig(max_batch=4, max_wait_s=0.001,
                         bucket_step=16, min_bucket=16)
    ocfg = OnlineConfig(eps0=0.0, eps_min=0.0)

    def run(backend, sub):
        task = GMRESIRTask(train, space, IR, bucket_step=16, min_bucket=16,
                           backend=backend)
        reg, _, _ = PolicyRegistry.warm_start(
            str(tmp_path / sub), task, W1, TrainConfig(episodes=2))
        serve_task = GMRESIRTask((), space, IR, bucket_step=16,
                                 min_bucket=16, backend=backend)
        srv = AutotuneServer(reg, serve_task, W1, bcfg, ocfg, seed=0)
        reqs = [randsvd_dense(int(n), 100.0, np.random.default_rng(100 + i))
                for i, n in enumerate((10, 13, 12, 14, 11, 9))]
        ids = [srv.submit(s) for s in reqs]
        srv.drain()
        return [srv.poll(i) for i in ids]

    resp_p = run(PALLAS, "p")
    resp_j = run(ORACLE, "j")
    for rp, rj in zip(resp_p, resp_j):
        assert rp.action == rj.action
        assert rp.record.status == rj.record.status
        assert rp.record.metrics == rj.record.metrics
        assert rp.reward == rj.reward


# ---------------------------------------------------------------------------
# Backend selection mechanics
# ---------------------------------------------------------------------------

@pytest.mark.fast
def test_pallas_falls_back_to_jnp_off_tpu():
    import jax
    if jax.default_backend() == "tpu":
        pytest.skip("on TPU the pallas backend is served compiled")
    assert resolve_backend("pallas").name == "jnp"
    assert resolve_backend("pallas-interpret").name == "pallas"


@pytest.mark.fast
def test_env_var_selects_default(monkeypatch):
    from repro.precision import backend as B
    monkeypatch.setenv(B.ENV_VAR, "pallas-interpret")
    assert resolve_backend(None).name == "pallas"
    monkeypatch.setenv(B.ENV_VAR, "jnp")
    assert resolve_backend(None).name == "jnp"


@pytest.mark.fast
def test_backends_hash_by_value():
    """Equal-valued backends must share one jit executable."""
    assert hash(PallasBackend(interpret=True)) == hash(
        PallasBackend(interpret=True))
    assert PallasBackend(interpret=True) == PallasBackend(interpret=True)
    assert JnpBackend() == JnpBackend()
    assert JnpBackend() != JnpBackend(carrier_dtype="float32")
