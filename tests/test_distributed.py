"""Distribution tests: sharding rules + a subprocess mini dry-run on a fake
8-device mesh (the 512-device production dry-run runs via launch/dryrun.py;
artifact validity is asserted here when present)."""
import glob
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def test_param_specs_rules():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_smoke
    from repro.distributed.sharding import param_specs, spec_for_param
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()  # 1 device: every axis size 1 -> all None
    # Use a synthetic 4x4 mesh instead for rule logic:
    from jax.sharding import Mesh
    devs = np.array(jax.devices() * 16)[:16].reshape(4, 4)
    mesh = Mesh(devs, ("data", "model"))

    s = spec_for_param("embed/embedding", (256, 64), mesh)
    assert s == P("model", ("data",))
    s = spec_for_param("layers/l0/mixer/wq", (2, 64, 128), mesh)
    assert s == P(None, ("data",), "model")
    s = spec_for_param("layers/l0/ffn/wi_gate", (2, 8, 64, 128), mesh)
    assert s == P(None, "model", ("data",), None)   # MoE expert bank
    s = spec_for_param("prefix/[0]/ffn/wi_gate", (64, 128), mesh)
    assert s == P(("data",), "model")               # dense FFN
    s = spec_for_param("layers/l0/ln1", (64,), mesh)
    assert s == P()
    # Divisibility: a dim not divisible by the axis drops the axis.
    s = spec_for_param("layers/l0/mixer/wq", (2, 63, 130), mesh)
    assert s == P(None, None, None)
    # Quantized moment leaves inherit the parent param's rule.
    s = spec_for_param("opt/m/layers/l0/mixer/wq/codes", (2, 64, 128), mesh)
    assert s == P(None, ("data",), "model")


def test_cache_specs_rules():
    import jax
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.distributed.sharding import cache_spec
    devs = np.array(jax.devices() * 16)[:16].reshape(4, 4)
    mesh = Mesh(devs, ("data", "model"))
    # batch shardable -> batch over data, heads over model
    assert cache_spec("layers/l0/k", (8, 1024, 4, 64), mesh) == \
        P(("data",), None, "model", None)
    # batch=1 long context -> sequence over data
    assert cache_spec("layers/l0/k", (1, 4096, 4, 64), mesh) == \
        P(None, ("data",), "model", None)
    # MLA latent cache
    assert cache_spec("layers/l0/ckv", (8, 1024, 32), mesh) == \
        P(("data",), None, None)
    # mamba state
    assert cache_spec("layers/l0/h", (8, 128, 4), mesh) == \
        P(("data",), "model", None)


MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
import numpy as np
from repro.configs import get_smoke
from repro.distributed.sharding import (batch_specs, named, param_specs,
                                        residual_spec)
from repro.launch.specs import train_batch_specs
from repro.models import init_params
from repro.train import AdamWConfig, TrainStepConfig, make_train_step
from repro.train.optimizer import adamw_init
from repro.train.train_step import TrainState
from repro.configs.base import ShapeConfig

cfg = get_smoke("jamba-v0.1-52b")   # exercises mamba+attn+MoE together
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     devices=jax.devices())
tcfg = TrainStepConfig(opt=AdamWConfig(quantize_moments=True,
                                       quant_block=16),
                       compute_dtype=jnp.float32)
step = make_train_step(cfg, tcfg,
                       residual_sharding=NamedSharding(mesh,
                                                       residual_spec(mesh)))
key = jax.random.PRNGKey(0)
state_shapes = jax.eval_shape(
    lambda k: TrainState(init_params(cfg, k, jnp.float32),
                         adamw_init(jax.eval_shape(
                             lambda kk: init_params(cfg, kk, jnp.float32),
                             k), tcfg.opt),
                         jnp.zeros((), jnp.int32)), key)
shape = ShapeConfig("mini", 64, 8, "train")
batch_shapes = train_batch_specs(cfg, shape)
state_sh = named(param_specs(state_shapes, mesh), mesh)
batch_sh = named(batch_specs(batch_shapes, mesh), mesh)
with mesh:
    lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                      out_shardings=(state_sh, None)).lower(state_shapes,
                                                            batch_shapes)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # newer JAX: per-module dicts
        cost = cost[0]
    assert float(cost.get("flops", 0)) > 0
    text = compiled.as_text()
assert ("all-reduce" in text) or ("all-gather" in text), "no collectives?!"
print("MINI_DRYRUN_OK")
"""


def test_mini_dryrun_8_devices():
    """Full sharded train-step lower+compile on a fake 2x2x2 pod mesh."""
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH",
                                                            ""))
    out = subprocess.run([sys.executable, "-c", MINI_DRYRUN], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "MINI_DRYRUN_OK" in out.stdout, out.stderr[-3000:]


@pytest.mark.skipif(not glob.glob(os.path.join(ART, "*.json")),
                    reason="production dry-run artifacts not generated yet")
def test_production_dryrun_artifacts_valid():
    """Every artifact the 512-device sweep produced is well-formed."""
    for p in glob.glob(os.path.join(ART, "*.json")):
        with open(p) as f:
            art = json.load(f)
        assert art["n_devices"] in (256, 512), p
        assert art.get("compile_s", 0) > 0, p
        if "flops" in art:
            assert art["flops"] > 0, p
            assert art["model_flops"] > 0, p
