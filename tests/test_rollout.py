"""Shadow/canary rollout controller: deterministic routing + shadow
mirroring, gate evaluation against snapshot-meta baselines, automatic
rollback of a degraded candidate and promotion of a healthy one, and
the end-to-end HTTP acceptance path (primary slice bit-identical to the
in-process `AutotuneServer`)."""
import json
import shutil
import urllib.request

import numpy as np
import pytest

from repro.core import GMRESIREnv, TrainConfig, W1, reduced_action_space
from repro.data import generate_dense_set
from repro.obs import MetricsRegistry, Observability
from repro.service import (AutotuneServer, BatcherConfig, OnlineConfig,
                           PolicyRegistry, RolloutConfig, ShadowServer)
from repro.service.http import HttpConfig, serve_http
from repro.solvers import IRConfig

SPACE = reduced_action_space()
IR = IRConfig(tau=1e-6)
BCFG = BatcherConfig(max_batch=4, max_wait_s=0.002, bucket_step=16,
                     min_bucket=16)
# Gates sized for the tiny test stream (under the x64 numerics the
# conftest pins). The degraded candidate is pinned to the all-bf16 arm
# (see _publish_degraded): bf16 residuals cannot reach tau=1e-6, so it
# stagnates — measured pass ~0.07-0.08 and reward EWMA ~-1.5..-0.5 on
# this kappa 1e3..1e6 stream, vs pass ~0.6-0.75 and reward ~9-14 for
# the trained policy. Both the absolute pass-rate floor (0.12) and the
# reward margin trip on it while a healthy copy clears both. The
# latency bound is slack (CI latency is noisy and not what these tests
# pin); each gate is also exercised deterministically against
# synthetic telemetry in test_gate_evaluation_unit.
RCFG = RolloutConfig(canary_frac=0.3, shadow=True, decision_window=24,
                     min_samples=20, promote_windows=2,
                     reward_margin=10.0, pass_rate_floor=0.12,
                     pass_rate_margin=0.9, p99_bound=50.0,
                     min_bucket_samples=4, seed=0)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _requests(n, seed, n_range=(12, 28)):
    rng = np.random.default_rng(seed)
    return generate_dense_set(n, rng, n_range, log10_kappa_range=(3, 6))


@pytest.fixture(scope="module")
def rollout_root(tmp_path_factory):
    """Warm-started registry whose CURRENT snapshot carries telemetry
    evidence in its meta (the gate baselines), produced the way
    production would: serve traffic, then `snapshot()`."""
    root = str(tmp_path_factory.mktemp("rollreg") / "reg")
    rng = np.random.default_rng(7)
    train = generate_dense_set(8, rng, n_range=(12, 28),
                               log10_kappa_range=(3, 6))
    env = GMRESIREnv(train, SPACE, IR, chunk=4, bucket_step=16)
    PolicyRegistry.warm_start(root, env, W1, TrainConfig(episodes=6))
    srv = AutotuneServer(PolicyRegistry(root), IR, W1, BCFG,
                         OnlineConfig(), seed=0, obs=False)
    for system in _requests(40, seed=3):
        srv.submit(system)
    srv.drain()
    srv.snapshot(note="baseline with telemetry evidence")
    return root


def _fork(root, tmp_path):
    """Private copy of the shared registry (tests mutate CURRENT)."""
    dst = str(tmp_path / "reg")
    shutil.copytree(root, dst)
    return PolicyRegistry(dst)


def _publish_degraded(reg):
    """Candidate pinned to action 0 — all-bf16 on every step, residuals
    included, so solves stagnate short of tau and the pass rate
    collapses. (Merely zeroing Q would NOT degrade anything: `greedy`
    breaks ties toward the highest action index, i.e. the safe
    all-fp64 arm.)"""
    pol = reg.load()
    pol.qtable.Q[:] = 0.0
    pol.qtable.Q[:, 0] = 1.0
    return reg.publish(pol, note="degraded: pinned to all-bf16")


def _publish_healthy(reg):
    return reg.publish(reg.load(), note="healthy: copy of baseline")


def _shadow(reg, clock=None, obs=False, rollout_cfg=RCFG, seed=0,
            decision_log_path=None):
    return ShadowServer(reg, IR, W1, BCFG, OnlineConfig(),
                        rollout_cfg=rollout_cfg,
                        clock=clock or FakeClock(), seed=seed, obs=obs,
                        decision_log_path=decision_log_path)


# ---------------------------------------------------------------------------
# Routing + shadow mirroring
# ---------------------------------------------------------------------------

def test_canary_routing_and_shadow_mirror(rollout_root, tmp_path):
    reg = _fork(rollout_root, tmp_path)
    baseline = reg.current_version()
    cfg = RolloutConfig(canary_frac=0.5, shadow=True,
                        decision_window=10**9, min_samples=10**9)
    shadow = _shadow(reg, rollout_cfg=cfg)
    cand = _publish_healthy(reg)
    shadow.start_rollout(cand)
    assert reg.current_version() == cand       # promote-at-start staging

    reqs = _requests(14, seed=5)
    rids = [shadow.submit(s) for s in reqs]
    shadow.drain()
    resps = {rid: shadow.poll(rid) for rid in rids}
    assert all(r is not None for r in resps.values())
    # Exactly-once retrieval.
    assert all(shadow.poll(rid) is None for rid in rids)

    primary = [r for r in resps.values() if r.policy_version == baseline]
    canary = [r for r in resps.values() if r.policy_version == cand]
    assert len(primary) + len(canary) == len(reqs)
    assert primary and canary                  # both slices took traffic
    # Shadow evaluation: the candidate solved its canary slice AND a
    # mirror of every primary-slice request, but only canary responses
    # were client-visible.
    assert shadow.candidate.telemetry.responses == len(reqs)
    state = shadow.rollout_state()
    assert state["phase"] == "canary" and state["active"]
    assert state["candidate_version"] == cand
    assert state["baseline_version"] == baseline


def test_routing_is_deterministic_per_seed(rollout_root, tmp_path):
    reqs = _requests(10, seed=11)

    def routes(tag):
        reg = _fork(rollout_root, tmp_path / tag)
        shadow = _shadow(reg, rollout_cfg=RCFG)
        shadow.start_rollout(_publish_healthy(reg))
        rids = [shadow.submit(s) for s in reqs]
        shadow.drain()
        return [shadow.poll(r).policy_version for r in rids]

    assert routes("a") == routes("b")


def test_gate_evaluation_unit(rollout_root, tmp_path):
    """Deterministic gate coverage with synthetic candidate telemetry:
    each hard floor (reward EWMA, pass rate, per-bucket p99) trips on
    exactly the evidence it reads."""
    reg = _fork(rollout_root, tmp_path)
    cfg = RolloutConfig(canary_frac=0.0, shadow=True,
                        decision_window=10**9, min_samples=4,
                        promote_windows=1, reward_margin=0.5,
                        pass_rate_floor=0.5, pass_rate_margin=0.25,
                        p99_bound=2.0, min_bucket_samples=2, seed=0)
    shadow = _shadow(reg, rollout_cfg=cfg)
    shadow.start_rollout(_publish_healthy(reg))
    shadow._baseline_tel = {"reward_ewma": 5.0, "converged_frac": 0.9,
                            "latency_s_per_bucket": {"16": {"p99": 0.01}}}
    tel = shadow.candidate.telemetry

    # Below min_samples: hold, no verdict on the other gates.
    d = shadow._evaluate_gates()
    assert d.outcome == "hold" and d.failures == ["min_samples"]

    # Healthy window: reward near baseline, all converged, fast.
    for i in range(8):
        tel.on_response(0.005, ("fp32",), 0, 4.8, now=float(i),
                        bucket=16, status=0)
    d = shadow._evaluate_gates()
    assert d.outcome == "promote" and not d.failures
    assert d.evidence["baseline_source"] == "snapshot_meta"
    assert d.evidence["pass_rate"]["floor"] == 0.65    # 0.9 - 0.25

    # Reward collapse: EWMA sinks below baseline - margin; pass rate
    # still fine, so the reward gate is the only failure.
    for i in range(8):
        tel.on_response(0.005, ("fp32",), 0, 0.0, now=float(8 + i),
                        bucket=16, status=0)
    d = shadow._evaluate_gates()
    assert d.outcome == "rollback" and d.failures == ["reward_ewma"]

    # Outcome failures + latency blowup: pass rate drops under the
    # floor and bucket-16 p99 exceeds bound * baseline p99.
    for i in range(10):
        tel.on_response(1.0, ("fp32",), 0, 4.8, now=float(16 + i),
                        bucket=16, status=3)
    d = shadow._evaluate_gates()
    assert d.outcome == "rollback"
    assert "pass_rate" in d.failures and "p99_bucket_16" in d.failures
    assert d.evidence["p99_per_bucket"]["16"]["baseline"] == 0.01


# ---------------------------------------------------------------------------
# Gate decisions: degraded rolls back, healthy promotes
# ---------------------------------------------------------------------------

def test_degraded_candidate_auto_rolls_back(rollout_root, tmp_path):
    reg = _fork(rollout_root, tmp_path)
    baseline = reg.current_version()
    log_path = str(tmp_path / "decisions.jsonl")
    obs = Observability(registry=MetricsRegistry())
    shadow = _shadow(reg, obs=obs, decision_log_path=log_path)
    vbad = _publish_degraded(reg)
    shadow.start_rollout(vbad)
    assert reg.current_version() == vbad

    reqs = _requests(48, seed=9)
    rids = []
    for system in reqs:
        rids.append(shadow.submit(system))
        shadow.step()
        if shadow.phase != "canary":
            break
    shadow.drain()
    assert shadow.phase == "rolled_back"
    assert reg.current_version() == baseline
    # The axe fell within (a small multiple of) one decision window.
    last = shadow.decisions[-1]
    assert last.outcome == "rollback"
    assert last.responses <= 3 * RCFG.decision_window
    assert last.failures                        # names the failed gates
    assert last.evidence["baseline_source"] == "snapshot_meta"

    # Decision-trail JSONL: start + the rollback decision + transition.
    events = [json.loads(ln) for ln in open(log_path) if ln.strip()]
    kinds = [e["event"] for e in events]
    assert kinds[0] == "start"
    assert "decision" in kinds and "rollback" in kinds
    decision = next(e for e in events if e["event"] == "decision"
                    and e["outcome"] == "rollback")
    assert decision["candidate"] == vbad
    assert decision["failures"]

    # rollout_decisions_total{outcome} counted.
    fam = {k: c.value for k, c in
           obs.registry.counter(
               "repro_rollout_decisions_total",
               "Canary gate decisions, by outcome.",
               ("task", "outcome"))._children.items()}
    assert any(k[1] == "rollback" and v >= 1 for k, v in fam.items())

    # In-flight canary requests still answer after the rollback.
    resps = [shadow.poll(rid) for rid in rids]
    assert all(r is not None for r in resps)


def test_healthy_candidate_auto_promotes(rollout_root, tmp_path):
    reg = _fork(rollout_root, tmp_path)
    shadow = _shadow(reg)
    vgood = _publish_healthy(reg)
    shadow.start_rollout(vgood)

    for system in _requests(60, seed=9):       # the same stream
        shadow.submit(system)
        shadow.step()
        if shadow.phase != "canary":
            break
    shadow.drain()
    assert shadow.phase == "promoted"
    assert reg.current_version() == vgood
    outcomes = [d.outcome for d in shadow.decisions]
    assert outcomes[-1] == "promote"
    assert outcomes.count("hold") >= RCFG.promote_windows - 1

    # The candidate now fronts all traffic.
    assert shadow.policy_version == vgood
    post = [shadow.submit(s) for s in _requests(6, seed=13)]
    shadow.drain()
    for rid in post:
        resp = shadow.poll(rid)
        assert resp is not None and resp.policy_version == vgood


def test_rollout_rejects_concurrent_start(rollout_root, tmp_path):
    reg = _fork(rollout_root, tmp_path)
    shadow = _shadow(reg)
    shadow.start_rollout(_publish_healthy(reg))
    with pytest.raises(RuntimeError):
        shadow.start_rollout(_publish_healthy(reg))


# ---------------------------------------------------------------------------
# End-to-end over HTTP (acceptance)
# ---------------------------------------------------------------------------

def _http(method, url, payload=None, timeout=60):
    data = (json.dumps(payload).encode("utf-8")
            if payload is not None else None)
    req = urllib.request.Request(
        url, data=data, method=method,
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, json.loads(r.read().decode("utf-8"))
    except urllib.error.HTTPError as e:
        body = e.read().decode("utf-8")
        return e.code, (json.loads(body) if body else {})


def _solve_payload(system):
    return {"A": system.A.tolist(), "b": system.b.tolist(),
            "x_true": system.x_true.tolist()}


def test_http_rollout_rolls_back_and_primary_slice_is_bit_identical(
        rollout_root, tmp_path):
    reg = _fork(rollout_root, tmp_path)
    baseline = reg.current_version()
    shadow = ShadowServer(reg, IR, W1, BCFG, OnlineConfig(),
                          rollout_cfg=RCFG, seed=0, obs=False)
    vbad = _publish_degraded(reg)
    shadow.start_rollout(vbad)
    fd = serve_http(shadow, cfg=HttpConfig(max_n=64,
                                           flush_interval_s=0.002))
    reqs = _requests(30, seed=21)              # mixed buckets: 16 and 32
    results = []
    try:
        for system in reqs:
            code, body = _http("POST", fd.url + "/v1/solve:sync",
                               _solve_payload(system))
            assert code == 200, body
            results.append(body)
            if shadow.phase != "canary":
                break
        # Controller decided without any explicit step() from us: the
        # background flush loop is the only pump.
        assert shadow.phase == "rolled_back"
        assert reg.current_version() == baseline
        code, pol = _http("GET", fd.url + "/v1/policy")
        assert code == 200
        assert pol["current"] == baseline
        assert pol["rollout"]["phase"] == "rolled_back"
        assert vbad in pol["versions"]
    finally:
        fd.close()

    # Primary-slice responses are bit-identical to a fresh in-process
    # AutotuneServer fed only the primary-slice subset (same seed, same
    # per-request flush cadence the sequential sync path produced).
    primary_idx = [i for i, r in enumerate(results)
                   if r["policy_version"] == baseline]
    assert primary_idx                          # slice took traffic
    ref = AutotuneServer(reg, IR, W1, BCFG, OnlineConfig(), seed=0,
                         obs=False)
    assert ref.policy_version == baseline       # rollback restored it
    for i in primary_idx:
        rid = ref.submit(reqs[i])
        ref.drain()
        want = ref.poll(rid)
        got = results[i]
        assert got["action"] == want.action
        assert got["state"] == want.state
        assert got["eps"] == want.eps
        assert got["action_names"] == list(want.action_names)
        assert got["outcome"]["status"] == want.record.status
        a, b = got["reward"], want.reward
        assert (a == b) or (not np.isfinite(a) and not np.isfinite(b))
        a, b = got["outcome"]["ferr"], float(want.record.ferr)
        assert (a == b) or (not np.isfinite(a) and not np.isfinite(b))


def test_http_rollout_promotes_healthy_candidate(rollout_root, tmp_path):
    reg = _fork(rollout_root, tmp_path)
    shadow = ShadowServer(reg, IR, W1, BCFG, OnlineConfig(),
                          rollout_cfg=RCFG, seed=0, obs=False)
    vgood = _publish_healthy(reg)
    shadow.start_rollout(vgood)
    fd = serve_http(shadow, cfg=HttpConfig(max_n=64,
                                           flush_interval_s=0.002))
    try:
        for system in _requests(60, seed=21):  # the same stream
            code, body = _http("POST", fd.url + "/v1/solve:sync",
                               _solve_payload(system))
            assert code == 200, body
            if shadow.phase != "canary":
                break
        assert shadow.phase == "promoted"
        assert reg.current_version() == vgood
        code, pol = _http("GET", fd.url + "/v1/policy")
        assert code == 200 and pol["rollout"]["phase"] == "promoted"
        # Post-promotion traffic is answered by the promoted policy.
        code, body = _http("POST", fd.url + "/v1/solve:sync",
                           _solve_payload(_requests(1, seed=33)[0]))
        assert code == 200 and body["policy_version"] == vgood
    finally:
        fd.close()
